"""Continuous-batching scheduler: slot invariants, exact token accounting,
chunked-prefill equivalence, online streaming-τ convergence, vectorized
traces."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.fpga import optimized_template, paper_workload
from repro.core.workload import (
    AccelProfile,
    break_even_tau,
    bursty_trace,
    irregular_trace,
    learn_tau,
    simulate,
)
from repro.serving.engine import InferenceEngine, ServeConfig, WorkloadAwareServer
from repro.serving.load import bursty_stream, diurnal_stream, poisson_stream
from repro.serving.policy import StreamingTauPolicy, make_policy
from repro.serving.scheduler import (
    ContinuousBatchingScheduler,
    FixedCalibration,
    run_static_batches,
)

# one representative per architecture family (dense / MLA-MoE / SSM / hybrid
# / audio) — the masked decode path must hold for every cache layout
FAMILY_ARCHS = ("granite-3-8b", "deepseek-v3-671b", "mamba2-780m",
                "zamba2-7b", "whisper-tiny")


def _engine(arch, max_batch=2, max_len=32):
    return InferenceEngine(get_reduced_config(arch),
                           sc=ServeConfig(max_batch=max_batch, max_len=max_len))


def _engine_f32(arch, max_batch=2, max_len=32):
    """Engine with everything float32: the chunked-vs-blocking equivalence is
    exact modulo float reassociation at chunk boundaries, and in f32 an
    argmax tie within that reassociation noise is measure-zero — bf16
    quantizes logits coarsely enough that near-ties flip."""
    from repro.models.model import init_model

    cfg = dataclasses.replace(get_reduced_config(arch), dtype=jnp.float32)
    params = jax.tree.map(lambda t: t.astype(jnp.float32),
                          init_model(cfg, jax.random.PRNGKey(0)))
    return InferenceEngine(cfg, params=params,
                           sc=ServeConfig(max_batch=max_batch, max_len=max_len))


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_scheduler_invariants_every_family(arch):
    eng = _engine(arch)
    reqs = poisson_stream(6, rate_hz=40.0, seed=1, vocab_size=eng.cfg.vocab_size,
                          prompt_lens=(4, 6), new_tokens=(1, 4))
    sched = ContinuousBatchingScheduler(eng, policy="adaptive")
    rep = sched.run(reqs)
    # no slot leaks: everything admitted finished and freed its slot
    assert sched.admitted == sched.completed == len(reqs)
    assert sched.pool.active_count == 0
    assert rep.items == len(reqs)
    # per-request token counts exact, ordering/latency sane
    by_rid = {rec.rid: rec for rec in rep.records}
    for r in reqs:
        rec = by_rid[r.rid]
        assert len(rec.tokens) == r.new_tokens
        assert all(0 <= t < eng.cfg.vocab_size for t in rec.tokens)
        assert rec.admit_s >= r.arrival_s
        assert rec.finish_s > rec.admit_s or r.new_tokens == 1
    assert rep.energy_j > 0 and rep.time_s > 0


def test_scheduler_matches_lockstep_generate_greedy():
    """A request served alone through the slot pool must reproduce the
    lockstep ``generate`` continuation token-for-token."""
    eng = _engine("granite-3-8b", max_batch=2, max_len=48)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, eng.cfg.vocab_size, 7).astype(np.int32)
    from repro.serving.load import Request

    reqs = [Request(rid=0, arrival_s=0.0, prompt=prompt, new_tokens=6)]
    rep = ContinuousBatchingScheduler(eng, policy="idle_waiting").run(reqs)
    ref = eng.generate(prompt[None], 6)[0].tolist()
    assert rep.records[0].tokens == ref


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_masked_decode_exact_under_staggered_occupancy(arch):
    """The masked decode path must match lockstep ``generate`` token-for-
    token for EVERY cache layout, including a second request admitted
    MID-DECODE of the first (mixed per-slot positions)."""
    eng = _engine(arch)
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, eng.cfg.vocab_size, 6).astype(np.int32)
    p2 = rng.integers(0, eng.cfg.vocab_size, 4).astype(np.int32)
    pool = eng.make_pool()
    toks1 = [eng.prefill_into_slot(pool, 0, p1, rid=1, budget=5)]
    toks2 = []
    for step in range(4):
        if step == 2:  # admit request 2 while request 1 is mid-decode
            toks2.append(eng.prefill_into_slot(pool, 1, p2, rid=2, budget=5))
        nxt, _ = eng.masked_decode_step(pool)
        for s in pool.active_slots():
            info = pool.slots[s]
            info.pos += 1
            info.emitted += 1
            pool.tok[s] = nxt[s]
            (toks1 if s == 0 else toks2).append(int(nxt[s]))
    assert toks1 == eng.generate(p1[None], 5)[0].tolist()
    ref2 = eng.generate(p2[None], 5)[0].tolist()
    assert toks2 == ref2[: len(toks2)] and len(toks2) == 3


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_chunked_scheduler_token_identical_every_family(arch):
    """ACCEPTANCE: chunked admission must emit token-for-token identical
    outputs to the blocking-prefill scheduler for every cache layout — the
    decode step is per-slot independent, so tokens depend only on each
    request's own prefilled cache, and the chunked cache must equal the
    blocking one."""
    eng = _engine_f32(arch, max_batch=3, max_len=48)
    reqs = bursty_stream(8, fast_rate_hz=2000.0, slow_rate_hz=20.0, seed=3,
                         vocab_size=eng.cfg.vocab_size, prompt_lens=(4, 9),
                         new_tokens=(1, 4))
    block = ContinuousBatchingScheduler(eng, policy="adaptive").run(reqs)
    sched = ContinuousBatchingScheduler(eng, policy="adaptive", prefill_chunk=4)
    chunk = sched.run(reqs)
    assert chunk.mode == "chunked" and chunk.chunks > 0
    assert sched.admitted == sched.completed == len(reqs)
    assert sched.pool.active_count == 0 and not sched.pool.admitting.any()
    tb = {r.rid: r.tokens for r in block.records}
    tc = {r.rid: r.tokens for r in chunk.records}
    assert tb == tc


def test_chunked_partial_and_oversized_chunks():
    """Chunk sizes that don't divide the prompt (final partial chunk) and
    chunks larger than the whole prompt must both reproduce blocking."""
    eng = _engine_f32("granite-3-8b", max_batch=2, max_len=48)
    from repro.serving.load import Request

    rng = np.random.default_rng(0)
    prompt = rng.integers(0, eng.cfg.vocab_size, 11).astype(np.int32)
    reqs = [Request(rid=0, arrival_s=0.0, prompt=prompt, new_tokens=5)]
    ref = ContinuousBatchingScheduler(eng, policy="idle_waiting").run(reqs)
    for chunk in (4, 32):
        rep = ContinuousBatchingScheduler(eng, policy="idle_waiting",
                                          prefill_chunk=chunk).run(reqs)
        assert rep.records[0].tokens == ref.records[0].tokens
        assert rep.chunks == -(-11 // chunk)


def test_chunked_same_length_group_admission():
    """A burst of same-prompt-length arrivals must admit as ONE batched
    group: ceil(s0/chunk) chunk calls total, identical admit times."""
    eng = _engine("whisper-tiny", max_batch=4, max_len=64)
    cal = FixedCalibration(step_s=0.004, prefill_base_s=0.001,
                           prefill_per_tok_s=5e-4)
    from repro.serving.load import Request

    reqs = [Request(rid=i, arrival_s=0.0, prompt=np.zeros(16, np.int32),
                    new_tokens=4) for i in range(3)]
    sched = ContinuousBatchingScheduler(eng, policy="idle_waiting",
                                        execute=False, calibration=cal,
                                        prefill_chunk=8)
    rep = sched.run(reqs)
    assert rep.chunks == 2  # one group of 3, 16 tokens in chunks of 8
    assert len({r.admit_s for r in rep.records}) == 1


def test_chunked_admission_fifo_across_bursts():
    """Admission order is FIFO in arrival order, across bursts and in both
    admission paths — same-length batching only groups CONSECUTIVE waiting
    requests, it never reorders past a different-length arrival."""
    eng = _engine("whisper-tiny", max_batch=4, max_len=64)
    cal = FixedCalibration(step_s=0.004, prefill_base_s=0.001,
                           prefill_per_tok_s=5e-4)
    reqs = bursty_stream(48, fast_rate_hz=400.0, slow_rate_hz=3.0, seed=7,
                         vocab_size=64, prompt_lens=(4, 8, 16),
                         new_tokens=(2, 8))
    for chunk in (None, 8):
        rep = ContinuousBatchingScheduler(eng, policy="adaptive",
                                          execute=False, calibration=cal,
                                          prefill_chunk=chunk).run(reqs)
        admits = [r.admit_s for r in sorted(rep.records, key=lambda r: r.rid)]
        assert all(a <= b for a, b in zip(admits, admits[1:]))  # FIFO


def test_slot_pool_free_list_and_admitting_state():
    """The explicit free-slot list stays the exact complement of active
    slots through reserve/activate/retire cycles, and admitting slots are
    excluded from the decode mask until activation."""
    from repro.serving.slots import SlotPool

    pool = SlotPool(get_reduced_config("whisper-tiny"), max_batch=4,
                    max_len=32, virtual=True)
    assert pool.free_slots() == [0, 1, 2, 3] and pool.free_count == 4
    pool.admit_virtual(0, rid=10, pos=4, budget=2)
    pool.reserve(1, rid=11)
    assert pool.free_slots() == [2, 3]
    assert pool.active_count == 2 and pool.decoding_count == 1
    assert pool.decoding_slots() == [0]
    pool.activate(1, None, rid=11, pos=8, budget=3, first_tok=0)
    assert pool.decoding_count == 2 and not pool.admitting.any()
    pool.retire(0)
    assert pool.free_slots() == [2, 3, 0]  # FIFO reuse: retired goes last
    assert pool.next_free() == 2
    with pytest.raises(AssertionError):
        pool.activate(2, None, rid=9, pos=1, budget=1, first_tok=0)  # not reserved


def test_policy_busy_hook_sees_mixed_ticks():
    """Duty-cycle policies observe every busy tick: with chunked admission
    the busy ledger splits into prefill and decode components."""
    eng = _engine("whisper-tiny", max_batch=2, max_len=64)
    cal = FixedCalibration(step_s=0.004, prefill_base_s=0.001,
                           prefill_per_tok_s=5e-4)
    reqs = poisson_stream(10, rate_hz=50.0, seed=0, vocab_size=64,
                          prompt_lens=(8, 16), new_tokens=(2, 6))
    sched = ContinuousBatchingScheduler(eng, policy="adaptive", execute=False,
                                        calibration=cal, prefill_chunk=8)
    sched.run(reqs)
    busy = sched.policy.busy_s
    assert busy["prefill"] > 0 and busy["decode"] > 0
    # at least one chunk-sized prefill tick per chunk at the calibrated floor
    assert busy["prefill"] >= sched.chunks * cal.prefill_s(1, 1)


def test_scheduler_queue_pressure_and_deadlines():
    """Burst far beyond pool capacity: requests queue, all complete, and the
    deadline accounting flows into the SimResult-compatible report."""
    eng = _engine("granite-3-8b", max_batch=2, max_len=32)
    reqs = bursty_stream(10, fast_rate_hz=5000.0, slow_rate_hz=50.0, seed=0,
                         vocab_size=eng.cfg.vocab_size, prompt_lens=(4,),
                         new_tokens=(2, 5), deadline_s=1e-4)
    sched = ContinuousBatchingScheduler(eng, policy="adaptive")
    rep = sched.run(reqs)
    assert rep.items == 10 and sched.pool.active_count == 0
    sim = rep.to_sim_result()
    assert sim.items == rep.items and sim.energy_j == rep.energy_j
    assert sim.missed_deadlines == sum(r.missed for r in rep.records)
    # an impossibly tight deadline under queue pressure must register misses
    assert sim.missed_deadlines > 0


def test_deadline_exactly_at_completion_is_on_time():
    """Boundary semantics: a request that finishes EXACTLY on its deadline
    is on time — ``missed`` uses strict >, and the admission feasibility
    estimate uses strict > too, so shedding leaves it alone. Power-of-two
    calibration costs make every sum in the virtual ledger exact, so the
    equality is bit-for-bit, not approximate."""
    from repro.serving.load import Request

    eng = _engine("whisper-tiny", max_batch=2, max_len=64)
    cal = FixedCalibration(step_s=2.0 ** -8, prefill_base_s=2.0 ** -10,
                           prefill_per_tok_s=2.0 ** -10)
    s0, nt = 8, 4
    exact = cal.prefill_s(1, s0) + (nt - 1) * cal.step_s()
    req = lambda d: [Request(rid=0, arrival_s=0.0,
                             prompt=np.zeros(s0, np.int32), new_tokens=nt,
                             deadline_s=d)]
    for shed in (False, True):
        rep = ContinuousBatchingScheduler(eng, policy="idle_waiting",
                                          execute=False, calibration=cal,
                                          shed=shed).run(req(exact))
        rec = rep.records[0]
        assert rec.latency_s == exact  # exact ledger arithmetic
        assert not rec.missed and not rec.shed
        assert rep.missed == 0 and rep.shed == 0 and rep.items == 1
        # one ulp tighter flips the verdict: shed up front when admission
        # control is on, a missed completion when it is off
        tight = ContinuousBatchingScheduler(eng, policy="idle_waiting",
                                            execute=False, calibration=cal,
                                            shed=shed).run(
            req(float(np.nextafter(exact, 0.0))))
        if shed:
            assert tight.shed == 1 and tight.items == 0
        else:
            assert tight.missed == 1 and tight.items == 1


def test_deadline_below_minimum_prefill_shed_vs_missed():
    """A deadline shorter than the bare prefill cost is infeasible for ANY
    schedule: admission control sheds it for zero tokens and zero request
    energy, while shed=False serves it anyway and books the miss — the two
    policies must agree it cannot be on time."""
    from repro.serving.load import Request

    eng = _engine("whisper-tiny", max_batch=2, max_len=64)
    cal = FixedCalibration(step_s=0.004, prefill_base_s=0.001,
                           prefill_per_tok_s=0.001)
    s0 = 8
    reqs = [Request(rid=0, arrival_s=0.0, prompt=np.zeros(s0, np.int32),
                    new_tokens=4, deadline_s=0.5 * cal.prefill_s(1, s0))]
    shed_rep = ContinuousBatchingScheduler(eng, policy="idle_waiting",
                                           execute=False, calibration=cal,
                                           shed=True).run(reqs)
    assert shed_rep.shed == 1 and shed_rep.items == 0
    rec = shed_rep.records[0]
    assert rec.shed and rec.tokens == [] and rec.energy_j == 0.0
    assert shed_rep.wasted_energy_j == 0.0  # shed pre-admission: nothing sunk
    serve = ContinuousBatchingScheduler(eng, policy="idle_waiting",
                                        execute=False, calibration=cal,
                                        shed=False).run(reqs)
    assert serve.shed == 0 and serve.missed == 1 and serve.items == 1
    assert len(serve.records[0].tokens) == 4  # served to completion anyway
    # serving the doomed request burns energy shedding saves
    assert serve.energy_j > shed_rep.energy_j
    assert serve.wasted_energy_j == serve.records[0].energy_j


def test_missed_accounting_consistent_across_modes():
    """One overloaded deadline stream through blocking, chunked, and
    speculative scheduling: in every mode a record is missed iff its latency
    exceeds its deadline, the report's ``missed`` matches the per-record
    count, and with shed=False nothing is ever dropped."""
    eng = InferenceEngine(get_reduced_config("whisper-tiny"),
                          sc=ServeConfig(max_batch=4, max_len=64, spec_slack=4))
    cal = FixedCalibration(step_s=0.004, prefill_base_s=0.001,
                           prefill_per_tok_s=5e-4)
    reqs = bursty_stream(24, fast_rate_hz=2000.0, slow_rate_hz=20.0, seed=5,
                         vocab_size=64, prompt_lens=(8, 16),
                         new_tokens=(4, 12), deadline_s=0.05)
    for mode_kw in (dict(), dict(prefill_chunk=8), dict(speculate_k=4)):
        rep = ContinuousBatchingScheduler(eng, policy="adaptive",
                                          execute=False, calibration=cal,
                                          **mode_kw).run(reqs)
        assert rep.items == 24 and rep.shed == 0 and rep.failed == 0
        assert rep.missed == sum(r.missed for r in rep.records)
        for r in rep.records:
            assert r.missed == (r.latency_s > 0.05)
        assert rep.missed > 0  # the burst genuinely overloads the pool


def test_virtual_scheduler_deterministic_and_continuous_wins():
    """Engine-free virtual run with fixed calibration: deterministic ledger,
    and continuous batching beats static batches on items/J AND p50 on a
    bursty stream (the benchmark's claim, in miniature)."""
    eng = _engine("whisper-tiny", max_batch=4, max_len=64)
    cal = FixedCalibration(step_s=0.004, prefill_base_s=0.003,
                           prefill_per_tok_s=2e-4)
    service = 0.003 + 12 * 0.004
    reqs = bursty_stream(60, fast_rate_hz=2.0 / service,
                         slow_rate_hz=0.02 / service, seed=2,
                         vocab_size=eng.cfg.vocab_size, prompt_lens=(4, 8),
                         new_tokens=(4, 24))
    run = lambda: ContinuousBatchingScheduler(
        eng, policy="adaptive", execute=False, calibration=cal).run(reqs)
    a, b = run(), run()
    assert a.energy_j == b.energy_j and a.p50_s == b.p50_s  # deterministic
    stat = run_static_batches(eng, reqs, policy="adaptive", execute=False,
                              calibration=cal, flush_s=16 * service)
    assert stat.items == a.items == 60
    assert a.items_per_joule > stat.items_per_joule
    assert a.p50_s < stat.p50_s


def test_online_tau_within_10pct_of_offline_learn_tau():
    """Acceptance: the streaming-τ policy on a stationary irregular
    (bimodal) stream lands within 10% of the offline learn_tau items/J."""
    prof = AccelProfile.from_template(optimized_template(), paper_workload())
    gaps = irregular_trace(prof, n=1200, seed=3)
    pol = StreamingTauPolicy(prof, window=400, refit_every=150, refit_steps=150)
    online_gap_e = sum(pol.on_gap(g).energy_j for g in gaps)
    online_e = prof.e_cfg_j + prof.p_active_w * prof.t_inf_s * gaps.size + online_gap_e
    online_ipj = gaps.size / online_e
    offline = simulate(gaps, "adaptive", prof, tau=learn_tau(gaps, prof))
    assert pol.refits > 0
    assert online_ipj >= 0.9 * offline.items_per_joule


def test_streaming_tau_adapts_to_regime_change():
    """τ must MOVE when the gap regime shifts across the break-even point."""
    prof = AccelProfile.from_template(optimized_template(), paper_workload())
    tau_be = break_even_tau(prof)
    pol = StreamingTauPolicy(prof, window=120, refit_every=60, refit_steps=120)
    rng = np.random.default_rng(0)
    for g in rng.uniform(0.05 * tau_be, 0.3 * tau_be, 120):  # short-gap regime
        pol.on_gap(float(g))
    tau_short = pol.tau
    for g in rng.uniform(5 * tau_be, 12 * tau_be, 240):  # long-gap regime
        pol.on_gap(float(g))
    assert pol.tau != tau_short  # the estimator tracked the shift


def test_policies_match_offline_gap_energies():
    """Each online policy's per-gap charge equals the offline simulate()
    ledger for its strategy (same AccelProfile, same gaps)."""
    prof = AccelProfile.from_template(optimized_template(), paper_workload())
    gaps = bursty_trace(prof, n=300, seed=1)
    e_inf = prof.p_active_w * prof.t_inf_s * gaps.size
    for name in ("on_off", "idle_waiting", "slow_down"):
        pol = make_policy(name, prof)
        total = prof.e_cfg_j + e_inf + sum(pol.on_gap(g).energy_j for g in gaps)
        ref = simulate(gaps, name, prof)
        assert total == pytest.approx(ref.energy_j, rel=1e-9), name


def test_run_trace_vectorized_matches_simulate():
    """WorkloadAwareServer.run_trace is now ONE simulate call — its ledger
    must equal the direct vectorized simulation, and compare_strategies with
    an explicit t_inf must not touch the server's measured-latency state."""
    eng = _engine("whisper-tiny")
    srv = WorkloadAwareServer(eng, strategy="adaptive")
    prof = srv.profile(0.01)
    gaps = bursty_trace(prof, n=500, seed=4)
    tau = break_even_tau(prof)
    stats = srv.run_trace(gaps, t_inf=0.01)
    ref = simulate(gaps, "adaptive", prof, tau=tau)
    assert stats.energy_j == pytest.approx(ref.energy_j)
    assert stats.items == ref.items
    assert stats.missed == ref.missed_deadlines
    assert stats.reloads == int(np.count_nonzero(gaps > tau))

    res = srv.compare_strategies(gaps, t_inf=0.01)
    assert srv._measured_t is None  # no side-channel mutation
    assert set(res) == {"on_off", "idle_waiting", "slow_down", "adaptive"}
    again = srv.compare_strategies(gaps, t_inf=0.01)
    for k in res:
        assert res[k].energy_j == again[k].energy_j  # stateless → reproducible


def test_bursty_trace_vectorized_statistics():
    """The numpy bursty trace keeps the Markov chain's distribution: mostly
    short burst gaps with a heavy quiet tail, deterministic per seed."""
    prof = AccelProfile.from_template(optimized_template(), paper_workload())
    tau_be = break_even_tau(prof)
    g = bursty_trace(prof, n=20000, seed=0)
    assert g.shape == (20000,) and (g > 0).all()
    np.testing.assert_array_equal(g, bursty_trace(prof, n=20000, seed=0))
    # busy fraction ~ 10/(10 + 1/0.7) ≈ 0.875 -> P(gap < tau_be) ≈ 0.89
    short_frac = np.mean(g < tau_be)
    assert 0.80 < short_frac < 0.95
    # mean ≈ 0.875·0.2τ + 0.125·5τ ≈ 0.8τ
    assert 0.5 * tau_be < g.mean() < 1.1 * tau_be


def test_load_generators_shapes_and_rates():
    for gen, kw in (
        (poisson_stream, dict(rate_hz=100.0)),
        (bursty_stream, dict(fast_rate_hz=200.0, slow_rate_hz=2.0)),
        (diurnal_stream, dict(base_rate_hz=10.0, peak_rate_hz=100.0, period_s=5.0)),
    ):
        reqs = gen(50, seed=0, vocab_size=64, prompt_lens=(4, 8),
                   new_tokens=(2, 6), **kw)
        assert len(reqs) == 50
        arr = np.asarray([r.arrival_s for r in reqs])
        assert (np.diff(arr) >= 0).all()  # timestamps sorted
        assert {len(r.prompt) for r in reqs} <= {4, 8}
        assert all(2 <= r.new_tokens <= 6 for r in reqs)
        assert all((r.prompt >= 0).all() and (r.prompt < 64).all() for r in reqs)
        assert [r.rid for r in reqs] == list(range(50))
