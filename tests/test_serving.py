"""Serving engine + workload-aware duty cycling."""
import numpy as np
import pytest

from repro.configs import get_reduced_config, list_archs
from repro.core.workload import break_even_tau, regular_trace
from repro.serving.engine import InferenceEngine, ServeConfig, WorkloadAwareServer
from repro.serving.kv_cache import cache_bytes, cache_defs


@pytest.mark.parametrize("arch", list_archs())
def test_generate_every_family(arch):
    cfg = get_reduced_config(arch)
    eng = InferenceEngine(cfg, sc=ServeConfig(max_batch=2, max_len=48))
    out = eng.generate(np.ones((2, 6), np.int32), 4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_generate_deterministic():
    cfg = get_reduced_config("granite-3-8b")
    eng = InferenceEngine(cfg, sc=ServeConfig(max_batch=2, max_len=48))
    p = np.arange(12, dtype=np.int32).reshape(2, 6) % cfg.vocab_size
    a = eng.generate(p, 5)
    b = eng.generate(p, 5)
    np.testing.assert_array_equal(a, b)


def test_cache_defs_bytes_scale_with_context():
    cfg = get_reduced_config("granite-3-8b")
    b1 = cache_bytes(cfg, batch=2, max_len=64)
    b2 = cache_bytes(cfg, batch=2, max_len=128)
    assert b2 == 2 * b1  # KV caches linear in context

    ssm = get_reduced_config("mamba2-780m")
    s1 = cache_bytes(ssm, batch=2, max_len=64)
    s2 = cache_bytes(ssm, batch=2, max_len=128)
    assert s1 == s2  # O(1) state — the long_500k enabler


def test_mla_cache_is_compressed():
    ds = get_reduced_config("deepseek-v3-671b")
    dense = get_reduced_config("granite-3-8b")
    import dataclasses

    # same geometry except the cache type
    mla_bytes = cache_bytes(ds, batch=2, max_len=128) / ds.num_layers
    kv_bytes = cache_bytes(dense, batch=2, max_len=128) / dense.num_layers
    m = ds.mla
    expect_ratio = (m.kv_lora_rank + m.qk_rope_head_dim) / (
        2 * dense.num_kv_heads * dense.resolved_head_dim
    )
    assert mla_bytes / kv_bytes == pytest.approx(expect_ratio, rel=0.01)


def test_strategy_choice_follows_gap_scale():
    cfg = get_reduced_config("granite-3-8b")
    eng = InferenceEngine(cfg, sc=ServeConfig(max_batch=2, max_len=48))
    srv = WorkloadAwareServer(eng, chips=1)
    t = srv.measure_latency(batch=2, new_tokens=2)
    prof = srv.profile(t)
    tau = break_even_tau(prof)

    short = regular_trace(0.05 * tau + t, t, 40)
    long_ = regular_trace(20 * tau + t, t, 40)
    res_s = srv.compare_strategies(short, batch=2, new_tokens=2, execute_every=40)
    res_l = srv.compare_strategies(long_, batch=2, new_tokens=2, execute_every=40)
    # short gaps: powering off must be the worst idea
    assert res_s["on_off"].items_per_joule <= res_s["idle_waiting"].items_per_joule
    # long gaps: staying configured must be the worst idea
    assert res_l["idle_waiting"].items_per_joule <= res_l["on_off"].items_per_joule
    # adaptive is never catastrophically behind the per-regime winner
    for res in (res_s, res_l):
        best = max(v.items_per_joule for v in res.values())
        assert res["adaptive"].items_per_joule >= 0.45 * best


def test_reload_energy_scales_with_model_size():
    small = get_reduced_config("whisper-tiny")
    big = get_reduced_config("qwen1.5-110b")
    e_small = WorkloadAwareServer(
        InferenceEngine(small, sc=ServeConfig(max_batch=1, max_len=32))
    ).e_reload
    e_big = WorkloadAwareServer(
        InferenceEngine(big, sc=ServeConfig(max_batch=1, max_len=32))
    ).e_reload
    assert e_big > 0 and e_small > 0
    # reload cost ordering follows weight bytes (the TPU "bitstream")
    assert (big.param_count() > small.param_count()) == (e_big > e_small)
