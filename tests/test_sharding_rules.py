"""Sharding rules: logical→mesh mapping, divisibility fallbacks, batch specs.

Uses a stub mesh (only ``.shape`` is consulted by the pure rule functions),
so no multi-device runtime is needed.
"""
import dataclasses

import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.params import ParamDef
from repro.sharding.rules import (
    ShardingRules,
    batch_axes,
    batch_spec,
    constrain,
    spec_for,
    tensor_parallel_rules,
)


class StubMesh:
    def __init__(self, shape: dict):
        self.shape = shape


SINGLE = StubMesh({"data": 16, "model": 16})
MULTI = StubMesh({"pod": 2, "data": 16, "model": 16})


def test_tp_axes_shard_when_divisible():
    rules = tensor_parallel_rules()
    d = ParamDef((4096, 32, 128), ("embed", "heads", None))
    assert spec_for(d, SINGLE, rules) == P(None, "model", None)
    d_ff = ParamDef((4096, 12800), ("embed", "mlp"))
    assert spec_for(d_ff, SINGLE, rules) == P(None, "model")


def test_indivisible_dims_fall_back_to_replication():
    rules = tensor_parallel_rules()
    # kv=1 (granite-34b MQA) cannot shard over a 16-way axis
    d = ParamDef((6144, 1, 128), ("embed", "kv_heads", None))
    assert spec_for(d, SINGLE, rules) == P(None, None, None)
    # whisper: 6 heads over 16-way model → replicated
    d = ParamDef((384, 6, 64), ("embed", "heads", None))
    assert spec_for(d, SINGLE, rules) == P(None, None, None)


def test_fsdp_shards_embed_axis_over_data():
    no = tensor_parallel_rules(fsdp=False)
    yes = tensor_parallel_rules(fsdp=True)
    d = ParamDef((8192, 64, 128), ("embed", "heads", None))
    assert spec_for(d, SINGLE, no) == P(None, "model", None)
    assert spec_for(d, SINGLE, yes) == P("data", "model", None)


def test_axis_used_only_once_per_tensor():
    rules = tensor_parallel_rules()
    # vocab and mlp both map to "model" — only the first dim gets it
    d = ParamDef((51200, 12800), ("vocab", "mlp"))
    sp = spec_for(d, SINGLE, rules)
    assert sp == P("model", None)


def test_stacked_layer_dim_never_sharded():
    rules = tensor_parallel_rules()
    d = ParamDef((40, 4096, 12800), ("layers", "embed", "mlp"))
    assert spec_for(d, SINGLE, rules) == P(None, None, "model")


def test_batch_axes_and_spec():
    assert batch_axes(SINGLE) == ("data",)
    assert batch_axes(MULTI) == ("pod", "data")
    assert batch_spec(256, SINGLE) == P("data", None)
    assert batch_spec(256, MULTI) == P(("pod", "data"), None)
    # batch=1 (long_500k) cannot shard → fully replicated
    assert batch_spec(1, MULTI) == P(None, None)
    # extra dims
    assert batch_spec(128, SINGLE, extra_dims=3) == P("data", None, None, None)


def test_constrain_is_identity_without_mesh():
    x = jnp.ones((4, 8))
    y = constrain(x, ("batch", None))
    assert y is x
