"""Speculative multi-token decode: exact greedy equivalence per family,
acceptance edge cases (accept-0 / accept-all / budget boundary / mixed
pools), drafter behaviour, and report accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.serving.draft import NgramDrafter
from repro.serving.engine import InferenceEngine, ServeConfig
from repro.serving.load import Request, bursty_stream, poisson_stream
from repro.serving.scheduler import ContinuousBatchingScheduler, FixedCalibration

FAMILY_ARCHS = ("granite-3-8b", "deepseek-v3-671b", "mamba2-780m",
                "zamba2-7b", "whisper-tiny")


def _engine_f32(arch, max_batch=2, max_len=32, slack=4):
    """f32 engine: speculative-vs-plain equivalence is exact modulo float
    reassociation (verify scores a K+1 window through the chunk path where
    plain decode steps one token at a time), and in f32 an argmax tie
    within that noise is measure-zero."""
    from repro.models.model import init_model

    cfg = dataclasses.replace(get_reduced_config(arch), dtype=jnp.float32)
    params = jax.tree.map(lambda t: t.astype(jnp.float32),
                          init_model(cfg, jax.random.PRNGKey(0)))
    return InferenceEngine(cfg, params=params,
                           sc=ServeConfig(max_batch=max_batch, max_len=max_len,
                                          spec_slack=slack))


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_speculative_token_identical_every_family(arch):
    """ACCEPTANCE: the speculative scheduler must emit token-for-token
    identical output to plain masked decode for every cache layout —
    including the SSM/hybrid recurrent-state rollback to the last accepted
    token, which a positional KV cache gets for free."""
    eng = _engine_f32(arch, max_batch=3, max_len=48)
    reqs = bursty_stream(8, fast_rate_hz=2000.0, slow_rate_hz=20.0, seed=3,
                         vocab_size=eng.cfg.vocab_size, prompt_lens=(4, 9),
                         new_tokens=(1, 6))
    block = ContinuousBatchingScheduler(eng, policy="adaptive").run(reqs)
    sched = ContinuousBatchingScheduler(eng, policy="adaptive", speculate_k=4)
    spec = sched.run(reqs)
    assert spec.mode == "speculative" and spec.verify_ticks > 0
    assert sched.admitted == sched.completed == len(reqs)
    assert sched.pool.active_count == 0
    assert {r.rid: r.tokens for r in block.records} == \
           {r.rid: r.tokens for r in spec.records}
    # every verify-committed token is accounted, and never fewer than one
    # token per tick per decoding slot (the accept-0 floor)
    assert spec.accepted_tokens == sum(len(r.tokens) - 1 for r in spec.records)
    assert spec.accepted_per_tick >= 1.0


def test_speculative_composes_with_chunked_admission():
    """Mixed decoding/admitting pools: chunked admission reserves slots
    whose prefill is in flight; the verify mask must exclude them and the
    combined scheduler still reproduces blocking output exactly."""
    eng = _engine_f32("granite-3-8b", max_batch=3, max_len=48)
    reqs = bursty_stream(8, fast_rate_hz=2000.0, slow_rate_hz=20.0, seed=3,
                         vocab_size=eng.cfg.vocab_size, prompt_lens=(4, 9),
                         new_tokens=(1, 6))
    block = ContinuousBatchingScheduler(eng, policy="adaptive").run(reqs)
    sched = ContinuousBatchingScheduler(eng, policy="adaptive",
                                        prefill_chunk=4, speculate_k=4)
    spec = sched.run(reqs)
    assert spec.mode == "speculative"
    assert spec.chunks > 0 and spec.verify_ticks > 0
    assert not sched.pool.admitting.any() and sched.pool.active_count == 0
    assert {r.rid: r.tokens for r in block.records} == \
           {r.rid: r.tokens for r in spec.records}


def test_verify_accept_all_and_accept_0():
    """Engine-level edges: perfect drafts accept all K (and the bonus token
    extends the chain); always-wrong drafts accept 0 and still commit
    exactly the plain-decode token each tick — never slower than plain
    decode in tokens emitted."""
    eng = _engine_f32("granite-3-8b", max_batch=2, max_len=48, slack=3)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, eng.cfg.vocab_size, 6).astype(np.int32)
    ref = eng.generate(prompt[None], 8)[0].tolist()

    pool = eng.make_pool()
    toks = [eng.prefill_into_slot(pool, 0, prompt, rid=0, budget=8)]
    toks += [eng.prefill_into_slot(pool, 1, prompt, rid=1, budget=8)]
    assert toks == ref[:1] * 2
    t_good, t_bad = [toks[0]], [toks[1]]
    ticks = 0
    while len(t_bad) < 8:
        drafts = np.zeros((2, 3), np.int32)
        i = len(t_good)
        drafts[0] = (ref[i:i + 3] + [0] * 3)[:3]          # oracle drafts
        drafts[1] = [(t + 1) % eng.cfg.vocab_size          # always wrong
                     for t in (ref[len(t_bad):len(t_bad) + 3] + [0] * 3)[:3]]
        out, acc, _ = eng.masked_speculative_step(pool, drafts)
        ticks += 1
        assert acc[1] == 0  # wrong drafts never accepted
        if len(t_good) < 8:
            n = min(int(acc[0]) + 1, 8 - len(t_good))
            t_good.extend(out[0, :n].tolist())
            pool.advance(0, n, int(out[0, n - 1]))
        t_bad.append(int(out[1, 0]))
        pool.advance(1, 1, int(out[1, 0]))
    assert t_good == ref and t_bad == ref
    # oracle drafts finish in ceil(7/4) ticks; accept-0 takes all 7
    assert ticks == 7
    # drafted surplus: tick 1 commits 3 drafts + bonus, tick 2 truncates at
    # the budget (2 drafts + 1); the accept-0 slot adds none
    assert pool.committed == 7 + 7 and pool.drafted == 5


def test_speculative_budget_boundary_no_overshoot():
    """A slot whose remaining budget is smaller than the accepted window
    retires mid-verify with EXACTLY its budget — acceptance past the budget
    is truncated, len(tokens) == new_tokens."""
    eng = _engine_f32("whisper-tiny", max_batch=2, max_len=32, slack=6)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, eng.cfg.vocab_size, 4).astype(np.int32)
    for budget in (1, 2, 3):
        reqs = [Request(rid=0, arrival_s=0.0, prompt=prompt, new_tokens=budget)]
        sched = ContinuousBatchingScheduler(eng, policy="idle_waiting",
                                            speculate_k=6)
        rep = sched.run(reqs)
        assert len(rep.records[0].tokens) == budget
        assert rep.records[0].tokens == eng.generate(prompt[None], budget)[0].tolist()
        assert sched.pool.active_count == 0


def test_speculative_requires_slack():
    """Engine/scheduler refuse a verify window larger than the spare cache
    rows — otherwise tail writes would clamp onto live positions."""
    eng = _engine_f32("granite-3-8b", max_batch=2, max_len=32, slack=2)
    with pytest.raises(ValueError, match="spec_slack"):
        ContinuousBatchingScheduler(eng, policy="adaptive", speculate_k=4)
    pool = eng.make_pool()
    with pytest.raises(AssertionError, match="spec_slack"):
        eng.masked_speculative_step(pool, np.zeros((2, 4), np.int32))


def test_ngram_drafter_suffix_and_fallback():
    d = NgramDrafter(3)
    d.begin(7, [1, 2, 3, 4, 1, 2])
    # suffix [1, 2] recurs at the start → replay what followed: 3, 4, 1
    assert d.propose(7).tolist() == [3, 4, 1]
    d.observe(7, [9])
    # no suffix ending in 9 recurs → period-1 fallback
    assert d.propose(7).tolist() == [9, 9, 9]
    d.forget(7)
    assert d.propose(7).tolist() == [0, 0, 0]  # unknown rid → zeros
    with pytest.raises(ValueError):
        NgramDrafter(0)


def test_virtual_speculative_ledger_deterministic():
    """Engine-free speculative run: the virtual model's greedy chain is all
    zeros, so the n-gram drafter locks on after one tick and the ledger is
    deterministic with verify ticks charged at step + K·per-candidate."""
    eng = InferenceEngine(get_reduced_config("whisper-tiny"),
                          sc=ServeConfig(max_batch=4, max_len=64, spec_slack=4))
    cal = FixedCalibration(step_s=0.004, prefill_base_s=0.001,
                           prefill_per_tok_s=5e-4, verify_per_tok_s=2e-4)
    assert cal.verify_s(4) == pytest.approx(0.004 + 4 * 2e-4)
    reqs = poisson_stream(12, rate_hz=50.0, seed=0, vocab_size=64,
                          prompt_lens=(8,), new_tokens=(4, 8))
    run = lambda: ContinuousBatchingScheduler(
        eng, policy="adaptive", execute=False, calibration=cal,
        speculate_k=4).run(reqs)
    a, b = run(), run()
    assert a.energy_j == b.energy_j and a.p50_s == b.p50_s
    assert a.verify_ticks > 0 and a.accepted_per_tick > 1.0
    plain = ContinuousBatchingScheduler(eng, policy="adaptive", execute=False,
                                        calibration=cal).run(reqs)
    # fewer busy ticks than one-token-per-slot decode on the same stream
    assert a.time_s < plain.time_s


def test_policy_sees_verify_ticks():
    """The duty-cycle busy ledger splits out verify ticks so policies can
    observe the speculative busy composition."""
    eng = InferenceEngine(get_reduced_config("whisper-tiny"),
                          sc=ServeConfig(max_batch=2, max_len=64, spec_slack=2))
    cal = FixedCalibration(step_s=0.004, prefill_base_s=0.001,
                           prefill_per_tok_s=5e-4, verify_per_tok_s=2e-4)
    reqs = poisson_stream(6, rate_hz=50.0, seed=0, vocab_size=64,
                          prompt_lens=(8,), new_tokens=(2, 6))
    sched = ContinuousBatchingScheduler(eng, policy="adaptive", execute=False,
                                        calibration=cal, speculate_k=2)
    rep = sched.run(reqs)
    busy = sched.policy.busy_s
    assert busy["prefill"] > 0 and busy["verify"] > 0 and "decode" not in busy
    assert busy["verify"] == pytest.approx(rep.verify_ticks * cal.verify_s(2))


def test_repetitive_prompts_lift_acceptance():
    """prompt_period tiling produces periodic prompts, and the drafter's
    acceptance on them exceeds 1 token per tick pool-wide."""
    reqs = bursty_stream(12, fast_rate_hz=200.0, slow_rate_hz=2.0, seed=0,
                         vocab_size=64, prompt_lens=(8, 16), new_tokens=(2, 6),
                         prompt_period=4)
    for r in reqs:
        p = r.prompt
        assert (p[4:] == p[: len(p) - 4]).all()  # period-4 tiling
    eng = _engine_f32("whisper-tiny", max_batch=4, max_len=32, slack=4)
    reqs = bursty_stream(6, fast_rate_hz=2000.0, slow_rate_hz=20.0, seed=1,
                         vocab_size=eng.cfg.vocab_size, prompt_lens=(4, 8),
                         new_tokens=(6, 12), prompt_period=4)
    rep = ContinuousBatchingScheduler(eng, policy="adaptive",
                                      speculate_k=4).run(reqs)
    assert rep.accepted_per_tick > 1.0
