"""End-to-end system behaviour: the paper's full loop on both backends.

application knowledge → Generator search → candidate → validation by
simulation / real engine execution — the RQ3 integration the paper's §2.3
calls "combined optimization evaluation".
"""
import numpy as np
import pytest

from repro.configs import get_config, get_reduced_config
from repro.core.candidates import DesignPoint
from repro.core.constraints import ApplicationSpec
from repro.core.cost_model import MeshPlan, TPUCostBackend
from repro.core.fpga import FPGACostBackend, optimized_template, paper_workload
from repro.core.generator import Generator, profile_of, score_candidate
from repro.core.workload import AccelProfile, bursty_trace, simulate


def test_fpga_end_to_end_generator_flow():
    w = paper_workload()
    backend = FPGACostBackend(workload=w)
    probe = AccelProfile.from_template(optimized_template(), w)
    gaps = bursty_trace(probe, n=1500, seed=3)
    app = ApplicationSpec(
        name="e2e", goal="energy_efficiency", max_latency_s=5e-3,
        resource_budget={"lut": 8000, "bram_kb": 360}, gaps=gaps,
    )
    res = Generator(backend, app).search(method="exhaustive")
    best = res.best
    # validation: re-simulate the winner; analytic score ≈ simulated
    prof = profile_of(best.estimate)
    sim = simulate(gaps, best.strategy, prof, tau=best.tau,
                   max_stretch=app.max_latency_s - best.estimate.latency_s)
    assert sim.items == len(gaps)
    assert sim.items_per_joule == pytest.approx(best.score, rel=0.05)
    # the winner beats the paper's fixed template under this app
    opt = optimized_template()
    paper_point = DesignPoint.of(n_mac=opt.n_mac, n_act=opt.n_act,
                                 act_impl=opt.act_impl, pipelined=opt.pipelined)
    paper_c = score_candidate(paper_point, backend.evaluate(paper_point), app)
    assert best.score >= paper_c.score * 0.999


def test_tpu_backend_same_generator_same_app_machinery():
    """The TPU extension plugs into the *identical* Generator/ApplicationSpec
    machinery — the paper's methodology transferred across hardware."""
    cfg = get_config("granite-3-8b")
    backend = TPUCostBackend(cfg, "decode_32k", MeshPlan(dp=16, tp=16))
    app = ApplicationSpec(name="pod", goal="energy_efficiency", period_s=1.0)
    res = Generator(backend, app).search(method="exhaustive", refine=False)
    assert res.ranked and res.best.score > 0
    # precision must appear as a real trade-off: int8 points dominate the
    # ranking's top under an energy goal, with a nonzero error cost
    assert res.best.point["precision"] == "int8"
    assert res.best.estimate.max_act_error > 0


def test_generator_choice_executes_on_real_engine():
    """The chosen duty-cycle strategy actually runs against the real
    inference engine and the measured items/J ordering matches the model."""
    from repro.serving.engine import InferenceEngine, ServeConfig, WorkloadAwareServer

    cfg = get_reduced_config("granite-3-8b")
    engine = InferenceEngine(cfg, sc=ServeConfig(max_batch=2, max_len=48))
    server = WorkloadAwareServer(engine, chips=1)
    t = server.measure_latency(batch=2, new_tokens=2)
    prof = server.profile(t)
    from repro.core.workload import break_even_tau, regular_trace

    gaps = regular_trace(30 * break_even_tau(prof) + t, t, 30)
    res = server.compare_strategies(gaps, batch=2, new_tokens=2, execute_every=30)
    # with gaps ≫ τ_be, powering off must beat idling (the paper's On-Off
    # regime) — verified with REAL measured latency in the loop
    assert res["on_off"].items_per_joule > res["idle_waiting"].items_per_joule
