"""End-to-end trainer integration: loss falls, failure-restart replays."""
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.data.pipeline import SyntheticLM
from repro.training.train_loop import Trainer, TrainerConfig


def tiny_cfg() -> ArchConfig:
    return ArchConfig(
        name="tiny-lm", family="dense", num_layers=2, d_model=96,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=256, remat="none",
    )


@pytest.fixture()
def ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")


def test_loss_decreases_and_survives_failure(ckpt_dir):
    cfg = tiny_cfg()
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=48, global_batch=8,
                     seed=0, branching=4)
    tc = TrainerConfig(num_steps=40, log_every=5, checkpoint_every=10,
                       checkpoint_dir=ckpt_dir, peak_lr=3e-3, warmup_steps=5)
    tr = Trainer(cfg, ds, tc)
    tr._failure_at = 23  # between checkpoints → must restore step 20 + replay
    stats = tr.run()
    assert stats["restarts"] == 1
    losses = [m["loss"] for m in stats["metrics"]]
    assert losses[-1] < losses[0] - 0.3, losses
    assert np.isfinite(losses).all()


def test_grad_accumulation_matches_single_batch():
    """accum=2 over one batch == accum=1 (same grads, same update)."""
    cfg = tiny_cfg()
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=1)
    import jax

    from repro.data.pipeline import make_batch
    from repro.models.model import init_model, param_defs
    from repro.models.params import init_params
    from repro.training.optimizer import Schedule, init_opt_state
    from repro.training.train_loop import make_train_step

    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    params = jax.tree.map(lambda t: t.astype(jnp.float32), params)
    opt = init_opt_state(cfg.optimizer, param_defs(cfg), params, key)
    batch = make_batch(cfg, ds, 0)
    sched = Schedule(peak_lr=1e-3, warmup_steps=0, total_steps=10)

    p1, _, m1 = jax.jit(make_train_step(cfg, sched, accum=1))(params, opt, batch, jnp.int32(3))
    p2, _, m2 = jax.jit(make_train_step(cfg, sched, accum=2))(params, opt, batch, jnp.int32(3))
    # microbatch losses average to the same value and updates agree closely
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-2)
