"""Training substrate: optimizers, checkpoints, fault tolerance, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.data.pipeline import SyntheticLM, make_batch, unigram_entropy_bits
from repro.models.model import param_defs
from repro.models.params import init_params
from repro.training.checkpoint import CheckpointManager
from repro.training.fault import RestartPolicy, StragglerDetector, WorkerFailure, run_with_restarts
from repro.training.optimizer import (
    Schedule,
    adafactor_state_defs,
    adamw_state_defs,
    clip_by_global_norm,
    global_norm,
    opt_state_defs,
    opt_update,
)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------
def _quadratic_params():
    return {"w": jnp.asarray([3.0, -2.0, 1.5]), "b": jnp.ones((2, 4)) * 2.0}


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_decreases_quadratic(name):
    params = _quadratic_params()
    defs = jax.tree.map(
        lambda t: __import__("repro.models.params", fromlist=["ParamDef"]).ParamDef(
            t.shape, tuple([None] * t.ndim), dtype=t.dtype
        ),
        params,
    )
    from repro.training.optimizer import init_opt_state
    state = init_opt_state(name, defs, params, KEY)

    def loss(p):
        return sum(jnp.sum(x * x) for x in jax.tree.leaves(p))

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = opt_update(name, params, g, state, 0.05)
    assert float(loss(params)) < 0.2 * l0
    assert int(state["step"]) == 60


def test_adafactor_state_is_factored():
    cfg = get_reduced_config("granite-3-8b")
    defs = param_defs(cfg)
    full = adamw_state_defs(defs)
    fact = adafactor_state_defs(defs)
    from repro.models.params import count_params

    assert count_params(fact["vr"]) + count_params(fact["vc"]) < 0.2 * count_params(full["m"])


def test_schedule_warmup_and_decay():
    s = Schedule(peak_lr=1e-3, warmup_steps=10, total_steps=100, min_ratio=0.1)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(s(5)) == pytest.approx(5e-4, rel=1e-5)
    assert float(s(100)) == pytest.approx(1e-4, rel=1e-3)
    lrs = [float(s(t)) for t in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(lrs, lrs[1:]))


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 10.0, "b": jnp.ones((5,)) * -10.0}
    clipped, norm = clip_by_global_norm(tree, max_norm=1.0)
    assert float(norm) == pytest.approx(float(global_norm(tree)))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.asarray([0.1])}
    same, _ = clip_by_global_norm(small, max_norm=1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [0.1], rtol=1e-6)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_with_bf16(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {
        "w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
        "opt": {"m": jnp.ones((2, 2), jnp.float32), "step": jnp.int32(7)},
    }
    mgr.save(5, tree, metadata={"loss": 1.25}, blocking=True)
    step, restored, meta = mgr.restore(like=tree)
    assert step == 5 and meta["loss"] == 1.25
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    assert mgr.latest_step() == 4
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_000003", "step_000004"]


def test_torn_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.zeros(3)}
    mgr.save(1, tree, blocking=True)
    # fabricate a torn (uncommitted) later checkpoint
    torn = tmp_path / "step_000002"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 1
    with pytest.raises(FileNotFoundError):
        mgr.restore(step=2, like=tree)


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.arange(5, dtype=jnp.float32)}
    mgr.save(3, tree, blocking=False)
    mgr.wait()
    step, restored, _ = mgr.restore(like=tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.arange(5, dtype=np.float32))


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------
def test_straggler_detector_flags_persistent_slowdown():
    det = StragglerDetector(warmup=5, patience=3, z_threshold=3.0)
    fired = []
    times = [0.10] * 20 + [0.50] * 6 + [0.10] * 5
    for i, t in enumerate(times):
        if det.observe(t):
            fired.append(i)
            det.reset()
    assert fired and 22 <= fired[0] <= 25  # third consecutive slow step


def test_straggler_detector_tolerates_jitter():
    rng = np.random.default_rng(0)
    det = StragglerDetector(warmup=5, patience=3)
    for t in 0.1 + 0.01 * rng.standard_normal(200):
        assert not det.observe(max(t, 0.05))


def test_run_with_restarts_replays_from_checkpoint():
    executed = []
    state = {"restored_to": None}

    def step_fn(step):
        executed.append(step)
        if step == 5 and state["restored_to"] is None:
            raise WorkerFailure("boom")

    def restore_fn():
        state["restored_to"] = 3
        return 3

    stats = run_with_restarts(
        step_fn, start_step=0, num_steps=8, restore_fn=restore_fn,
        policy=RestartPolicy(max_restarts=2), sleep=lambda s: None,
    )
    assert stats["restarts"] == 1
    assert executed == [0, 1, 2, 3, 4, 5, 3, 4, 5, 6, 7]  # deterministic replay


def test_run_with_restarts_gives_up():
    def step_fn(step):
        raise WorkerFailure("always")

    with pytest.raises(WorkerFailure):
        run_with_restarts(
            step_fn, start_step=0, num_steps=3, restore_fn=lambda: 0,
            policy=RestartPolicy(max_restarts=2), sleep=lambda s: None,
        )


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_distinct():
    ds = SyntheticLM(vocab_size=128, seq_len=32, global_batch=8, seed=1, num_hosts=2)
    a = ds.batch(step=3, host=0)
    b = ds.batch(step=3, host=0)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = ds.batch(step=4, host=0)
    d = ds.batch(step=3, host=1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(d["tokens"]))


def test_labels_are_next_tokens_from_chain():
    ds = SyntheticLM(vocab_size=64, seq_len=16, global_batch=4, seed=0, branching=4)
    batch = ds.batch(0)
    toks, labels = np.asarray(batch["tokens"]), np.asarray(batch["labels"])
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])  # shifted view
    chain = ds._chain()
    # every label is a legal successor of its token under the bigram chain
    for b in range(toks.shape[0]):
        for t in range(toks.shape[1]):
            assert labels[b, t] in chain[toks[b, t]]
    assert unigram_entropy_bits(ds) == 2.0


def test_vlm_batch_masks_frontend_positions():
    cfg = get_reduced_config("internvl2-76b")
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=2)
    batch = make_batch(cfg, ds, step=0)
    assert batch["frontend_embeds"].shape == (2, cfg.frontend_seq, cfg.d_model)
    labels = np.asarray(batch["labels"])
    assert (labels[:, : cfg.frontend_seq] == -1).all()
    assert (labels[:, cfg.frontend_seq :] >= 0).all()
